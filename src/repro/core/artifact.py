"""TableArtifact — the deployable output of IIsy's mapping tool.

The artifact is what the control plane would load into switch tables. Every
array is a *runtime input* to the jitted inference step (never a baked
constant), so retraining swaps tables without recompiling — the paper's
"model updates by table updates only" property (§4.4).

Two families share the container:

Tree ensembles (dt / rf / xgb / iforest):
  edges   (F, U)      union of the ensemble's thresholds per feature (+inf pad)
  ftable  (F, U+1, T) per-union-bin, per-tree code (tree-local bin rank)
  strides (T, F)      mixed-radix strides turning codes into a decision key
  dtable_class (T, S) leaf class id per key              (vote aggregation)
  dtable_value (T, S) quantized leaf payload per key     (weight / path len)

Classical (svm / nb / kmeans):
  edges   (F, U)      quantile bin edges (+inf pad)
  vtable  (F, U+1, M) quantized per-bin partial terms
                      M = hyperplanes | classes | clusters
  consts  (M,)        intercept sums / log priors / zeros

Fused-kernel layout (built once, control-plane side, by
``finalize_artifact``; see DESIGN.md §2):

  ftable_flat (F*Bp, Tp)   f32  stride-premultiplied flattened feature table:
                                flat[f*Bp + b, t] = ftable[f, b, t] * strides[t, f]
  vtable_flat (F*Bp, Mp)   f32  flattened quantized partial terms
  dtable_flat (Co, T, Sp)  f32  decision+aggregation matmul table:
                                Co = n_classes (vote: one-hot of the leaf
                                class) or 1 (sum aggs: quantized payload)
  dtable_pad  (T, Sp)      f32  lane-padded raw decision table (class ids or
                                payloads) for the compare-select strategy
                                used when T*Sp is too large for the matmul
                                select to pay off

where Bp/Tp/Mp/Sp are U+1/T/M/S rounded up to the lane boundary so every
matmul/compare operand is lane-aligned on the MXU/VPU (``default_lane``:
128 on TPU where alignment is mandatory and padding is free in the
systolic tile; 8 elsewhere, where padded columns cost real FLOPs). Padded
bins/trees/columns are zero and — because bins <= U and keys < S — can
never be selected, so the fused kernels stay bit-exact. The logical shapes
remain recoverable from the unpadded arrays (``pad_meta``); epilogues
slice padded outputs back to logical width. All values involved are
integers riding as f32 (< 2^24), so one big matmul is exact.

The dtable_flat layout is what lets the kernel run the *entire*
decision-table walk AND the aggregation as one more one-hot matmul:
out[n, c] = sum_{t,s} (keys[n,t] == s) * dtable_flat[c, t, s] — votes or
payload totals fall straight out of the contraction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import FixedPoint

LANE = 128   # TPU lane width: last-dim alignment unit for MXU/VPU operands


def default_lane() -> int:
    """Pad-to lane width: 128 on TPU (mandatory MXU/VPU alignment, free in
    the systolic tile), 8 elsewhere (padding is real FLOPs off-TPU, so only
    align to the smallest vector-friendly multiple)."""
    return LANE if jax.default_backend() == "tpu" else 8


def round_up_to_lane(n: int, lane: int = LANE) -> int:
    return -(-n // lane) * lane


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TableArtifact:
    # shared
    edges: jax.Array
    agg: str = dataclasses.field(metadata=dict(static=True))
    # 'vote' | 'wsum_sigmoid' | 'iforest' | 'svm_ovo' | 'nb_log' | 'kmeans'
    n_classes: int = dataclasses.field(metadata=dict(static=True))

    # tree family
    ftable: Optional[jax.Array] = None
    strides: Optional[jax.Array] = None
    dtable_class: Optional[jax.Array] = None
    dtable_value: Optional[FixedPoint] = None

    # classical family
    vtable: Optional[FixedPoint] = None
    consts: Optional[jax.Array] = None

    # svm extras
    pairs: Optional[jax.Array] = None          # (m, 2) class pairs

    # fused single-matmul kernel layout (see finalize_artifact)
    ftable_flat: Optional[jax.Array] = None    # (F*Bp, Tp) f32
    vtable_flat: Optional[jax.Array] = None    # (F*Bp, Mp) f32
    dtable_flat: Optional[jax.Array] = None    # (Co, T, Sp) f32
    dtable_pad: Optional[jax.Array] = None     # (T, Sp) f32

    # scalars used by aggregation
    base_score: float = dataclasses.field(metadata=dict(static=True), default=0.0)
    learning_rate: float = dataclasses.field(metadata=dict(static=True), default=1.0)
    iforest_subsample: float = dataclasses.field(metadata=dict(static=True), default=256.0)

    @property
    def n_features(self) -> int:
        return self.edges.shape[0]

    @property
    def n_trees(self) -> int:
        return 0 if self.ftable is None else self.ftable.shape[2]

    @property
    def n_bins(self) -> int:
        """Logical bins per feature (union edge count + 1)."""
        return self.edges.shape[1] + 1

    @property
    def pad_meta(self) -> dict:
        """Padded vs logical shapes — how to slice the logical view back out."""
        meta = {"b": self.n_bins}
        if self.ftable_flat is not None:
            meta.update(b_pad=self.ftable_flat.shape[0] // self.n_features,
                        t=self.n_trees, t_pad=self.ftable_flat.shape[1],
                        s=self.dtable_class.shape[1],
                        s_pad=self.dtable_flat.shape[2])
        if self.vtable_flat is not None:
            meta.update(b_pad=self.vtable_flat.shape[0] // self.n_features,
                        m=self.vtable.q.shape[2],
                        m_pad=self.vtable_flat.shape[1])
        return meta


# ---------------------------------------------------------------------------
# fused-kernel table layout
# ---------------------------------------------------------------------------

def flatten_ftable(ftable, strides, lane: Optional[int] = None) -> jax.Array:
    """(F, B, T) codes + (T, F) strides -> (F*Bp, Tp) f32, stride-premultiplied.

    Folding the mixed-radix stride into the table turns the whole stage-2
    key computation into ONE one-hot matmul: keys = blocked_onehot @ flat.
    code * stride < S <= 2^24, so the product is exact in f32.
    """
    lane = lane or default_lane()
    f, b, t = ftable.shape
    b_pad = round_up_to_lane(b, lane)
    t_pad = round_up_to_lane(t, lane)
    prod = (ftable.astype(jnp.float32)
            * jnp.transpose(strides).astype(jnp.float32)[:, None, :])  # (F,B,T)
    flat = jnp.zeros((f, b_pad, t_pad), jnp.float32)
    flat = flat.at[:, :b, :t].set(prod)
    return flat.reshape(f * b_pad, t_pad)


def flatten_vtable(q, lane: Optional[int] = None) -> jax.Array:
    """(F, B, M) quantized terms -> (F*Bp, Mp) f32 (exact integer payloads)."""
    lane = lane or default_lane()
    f, b, m = q.shape
    b_pad = round_up_to_lane(b, lane)
    m_pad = round_up_to_lane(m, lane)
    flat = jnp.zeros((f, b_pad, m_pad), jnp.float32)
    flat = flat.at[:, :b, :m].set(q.astype(jnp.float32))
    return flat.reshape(f * b_pad, m_pad)


def build_dtable_flat(dtable, n_classes: int, vote: bool,
                      lane: Optional[int] = None) -> jax.Array:
    """(T, S) decision table -> (Co, T, Sp) f32 decision+aggregation table.

    vote: Co = n_classes and flat[c, t, s] = (dtable[t, s] == c) — the
    match one-hot matmul then counts per-class votes directly.
    sums: Co = 1 and flat[0, t, s] = dtable[t, s] — the matmul sums the
    matched payloads across trees.

    Pad entries sit at key indices >= S, which no decision key can take
    (keys < per-tree size <= S), so zeros there keep the matmul exact.
    """
    lane = lane or default_lane()
    t, s = dtable.shape
    s_pad = round_up_to_lane(s, lane)
    if vote:
        c_iota = jnp.arange(n_classes, dtype=jnp.float32)
        flat = (dtable.astype(jnp.float32)[None, :, :]
                == c_iota[:, None, None]).astype(jnp.float32)
    else:
        flat = dtable.astype(jnp.float32)[None, :, :]
    out = jnp.zeros((flat.shape[0], t, s_pad), jnp.float32)
    return out.at[:, :, :s].set(flat)


def pad_dtable(dtable, lane: Optional[int] = None) -> jax.Array:
    """(T, S) -> (T, Sp) f32 for the compare-select strategy. Pad entries
    can never match (keys < S), so their value is irrelevant."""
    lane = lane or default_lane()
    t, s = dtable.shape
    s_pad = round_up_to_lane(s, lane)
    out = jnp.zeros((t, s_pad), jnp.float32)
    return out.at[:, :s].set(dtable.astype(jnp.float32))


def finalize_artifact(art: TableArtifact,
                      lane: Optional[int] = None,
                      profile=None) -> TableArtifact:
    """Attach the fused single-matmul kernel layout (idempotent).

    Runs control-plane side, once per table load — the runtime hot path only
    ever consumes the pre-flattened arrays.

    profile: optional ``core.resources.DeviceProfile`` deploy guard —
    the artifact is checked against the device budget *before* any
    layout work and a ``FitError`` aborts the load if it cannot deploy
    (Planter-style fit gate; see ``core.resources.check_fit``). None
    (default) keeps finalization unconditional.
    """
    if profile is not None:
        # local import: resources imports this module for TableArtifact
        from repro.core.resources import check_fit
        check_fit(art, profile, strict=True)
    lane = lane or default_lane()
    if art.ftable is not None:
        if art.ftable_flat is not None:
            return art
        vote = art.agg == "vote"
        dtable = art.dtable_class if vote else art.dtable_value.q
        return dataclasses.replace(
            art,
            ftable_flat=flatten_ftable(art.ftable, art.strides, lane),
            dtable_flat=build_dtable_flat(dtable, art.n_classes, vote, lane),
            dtable_pad=pad_dtable(dtable, lane))
    if art.vtable is not None:
        if art.vtable_flat is not None:
            return art
        return dataclasses.replace(
            art, vtable_flat=flatten_vtable(art.vtable.q, lane))
    return art
