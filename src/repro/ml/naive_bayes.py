"""Gaussian naive Bayes (closed-form fit, log-domain prediction)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GaussianNB:
    mu: jax.Array          # (C, F)
    var: jax.Array         # (C, F)
    log_prior: jax.Array   # (C,)
    n_classes: int = dataclasses.field(metadata=dict(static=True), default=2)


def fit_gaussian_nb(x, y, *, n_classes, var_smoothing=1e-6):
    x = jnp.asarray(x, jnp.float32)
    y1h = jax.nn.one_hot(jnp.asarray(y), n_classes, dtype=jnp.float32)
    count = jnp.maximum(y1h.sum(0), 1.0)                       # (C,)
    mu = (y1h.T @ x) / count[:, None]                          # (C, F)
    sq = (y1h.T @ (x * x)) / count[:, None]
    var = jnp.maximum(sq - mu * mu, 0.0) + var_smoothing * x.var(0).max()
    log_prior = jnp.log(count / count.sum())
    return GaussianNB(mu=mu, var=var, log_prior=log_prior, n_classes=n_classes)


def nb_log_likelihood(model: GaussianNB, x) -> jax.Array:
    """Per-class joint log likelihood log P(y) + sum_i log P(x_i|y). (N, C)."""
    x = jnp.asarray(x, jnp.float32)
    d = x[:, None, :] - model.mu[None, :, :]                   # (N, C, F)
    ll = -0.5 * (jnp.log(2 * jnp.pi * model.var)[None] + d * d / model.var[None])
    return model.log_prior[None, :] + ll.sum(-1)


def predict_nb(model: GaussianNB, x) -> jax.Array:
    return jnp.argmax(nb_log_likelihood(model, x), axis=1)
