"""Linear SVM with one-vs-one hyperplanes, trained by hinge-loss SGD in JAX.

The training output is exactly what IIsy's SVM mapping (§A.1) consumes: the
hyperplane equations ``a·x + d`` for each of the m = k(k-1)/2 class pairs.
"""

from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LinearSVM:
    weights: jax.Array     # (m, F) hyperplane normals
    bias: jax.Array        # (m,)
    pairs: jax.Array       # (m, 2) int32 class pair (i, j); sign>0 votes i
    mean: jax.Array        # (F,) feature standardization
    scale: jax.Array       # (F,)
    n_classes: int = dataclasses.field(metadata=dict(static=True), default=2)


def _fit_binary(x, y_pm, key, epochs, lr, reg):
    """Full-batch subgradient descent on hinge loss. y_pm in {-1, +1}."""
    n, f = x.shape
    w0 = jnp.zeros((f,), jnp.float32)
    b0 = jnp.zeros((), jnp.float32)

    def step(carry, i):
        w, b = carry
        margin = y_pm * (x @ w + b)
        active = (margin < 1.0).astype(jnp.float32)
        gw = reg * w - (active * y_pm) @ x / n
        gb = -jnp.mean(active * y_pm)
        eta = lr / (1.0 + 0.01 * i)
        return (w - eta * gw, b - eta * gb), None

    (w, b), _ = jax.lax.scan(step, (w0, b0), jnp.arange(epochs))
    return w, b


def fit_linear_svm(x, y, *, n_classes, epochs=300, lr=0.5, reg=1e-3, seed=0):
    x = jnp.asarray(x, jnp.float32)
    y = np.asarray(y)
    mean = x.mean(0)
    scale = jnp.maximum(x.std(0), 1e-6)
    xs = (x - mean) / scale

    pairs = list(itertools.combinations(range(n_classes), 2))
    ws, bs = [], []
    key = jax.random.PRNGKey(seed)
    fit = jax.jit(_fit_binary, static_argnums=(3,))
    for (i, j) in pairs:
        m = (y == i) | (y == j)
        xij = xs[np.where(m)[0]]
        y_pm = jnp.where(jnp.asarray(y[m]) == i, 1.0, -1.0)
        w, b = fit(xij, y_pm, key, epochs, lr, reg)
        ws.append(w); bs.append(b)
    return LinearSVM(weights=jnp.stack(ws), bias=jnp.stack(bs),
                     pairs=jnp.asarray(pairs, jnp.int32),
                     mean=mean, scale=scale, n_classes=n_classes)


def svm_decision_values(model: LinearSVM, x) -> jax.Array:
    """Raw hyperplane values (N, m) — the quantity IIsy tabulates."""
    xs = (jnp.asarray(x, jnp.float32) - model.mean) / model.scale
    return xs @ model.weights.T + model.bias


def predict_svm(model: LinearSVM, x) -> jax.Array:
    vals = svm_decision_values(model, x)               # (N, m)
    n = vals.shape[0]
    votes = jnp.zeros((n, model.n_classes), jnp.float32)
    win_i = (vals > 0)
    votes = votes.at[:, model.pairs[:, 0]].add(win_i.astype(jnp.float32))
    votes = votes.at[:, model.pairs[:, 1]].add((~win_i).astype(jnp.float32))
    return jnp.argmax(votes, axis=1)
