"""Classification metrics used by the paper's tables (acc/P/R/F1)."""

from __future__ import annotations

import jax.numpy as jnp


def confusion_matrix(y_true, y_pred, n_classes):
    y_true = jnp.asarray(y_true, jnp.int32)
    y_pred = jnp.asarray(y_pred, jnp.int32)
    idx = y_true * n_classes + y_pred
    cm = jnp.zeros((n_classes * n_classes,), jnp.int32).at[idx].add(1)
    return cm.reshape(n_classes, n_classes)


def accuracy(y_true, y_pred):
    return float(jnp.mean(jnp.asarray(y_true) == jnp.asarray(y_pred)))


def precision_recall_f1(y_true, y_pred, positive=1):
    """Binary P/R/F1 treating ``positive`` as the positive class."""
    y_true = jnp.asarray(y_true); y_pred = jnp.asarray(y_pred)
    tp = jnp.sum((y_pred == positive) & (y_true == positive))
    fp = jnp.sum((y_pred == positive) & (y_true != positive))
    fn = jnp.sum((y_pred != positive) & (y_true == positive))
    p = tp / jnp.maximum(tp + fp, 1)
    r = tp / jnp.maximum(tp + fn, 1)
    f1 = 2 * p * r / jnp.maximum(p + r, 1e-9)
    return float(p), float(r), float(f1)


def macro_f1(y_true, y_pred, n_classes):
    f1s = [precision_recall_f1(y_true, y_pred, positive=c)[2]
           for c in range(n_classes)]
    return sum(f1s) / n_classes
