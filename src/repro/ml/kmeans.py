"""K-means (k-means++ init, Lloyd iterations, jit'd)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KMeansModel:
    centers: jax.Array     # (K, F)
    mean: jax.Array        # (F,) standardization applied before clustering
    scale: jax.Array       # (F,)


def _plusplus_init(xs, k, key):
    n = xs.shape[0]
    i0 = jax.random.randint(key, (), 0, n)
    centers = jnp.zeros((k, xs.shape[1]), xs.dtype).at[0].set(xs[i0])

    def pick(carry, i):
        centers, key = carry
        key, sub = jax.random.split(key)
        d2 = jnp.min(
            jnp.sum((xs[:, None, :] - centers[None, :, :]) ** 2, -1)
            + jnp.where(jnp.arange(k)[None, :] < i, 0.0, jnp.inf), axis=1)
        p = d2 / jnp.maximum(d2.sum(), 1e-9)
        idx = jax.random.choice(sub, n, (), p=p)
        return (centers.at[i].set(xs[idx]), key), None

    (centers, _), _ = jax.lax.scan(pick, (centers, key), jnp.arange(1, k))
    return centers


def fit_kmeans(x, *, k, iters=50, seed=0):
    x = jnp.asarray(x, jnp.float32)
    mean = x.mean(0)
    scale = jnp.maximum(x.std(0), 1e-6)
    xs = (x - mean) / scale

    @jax.jit
    def run(key):
        centers = _plusplus_init(xs, k, key)

        def lloyd(centers, _):
            d2 = jnp.sum((xs[:, None, :] - centers[None, :, :]) ** 2, -1)
            assign = jnp.argmin(d2, axis=1)
            oh = jax.nn.one_hot(assign, k, dtype=xs.dtype)      # (N, K)
            counts = jnp.maximum(oh.sum(0), 1.0)
            new = (oh.T @ xs) / counts[:, None]
            keep = (oh.sum(0) > 0)[:, None]
            return jnp.where(keep, new, centers), None

        centers, _ = jax.lax.scan(lloyd, centers, None, length=iters)
        return centers

    return KMeansModel(centers=run(jax.random.PRNGKey(seed)),
                       mean=mean, scale=scale)


def kmeans_sq_dists(model: KMeansModel, x) -> jax.Array:
    xs = (jnp.asarray(x, jnp.float32) - model.mean) / model.scale
    return jnp.sum((xs[:, None, :] - model.centers[None, :, :]) ** 2, -1)


def predict_kmeans(model: KMeansModel, x) -> jax.Array:
    return jnp.argmin(kmeans_sq_dists(model, x), axis=1)
