"""Histogram-based tree learners with fixed-shape, jit-compatible training.

All trees are *complete* binary trees of a fixed ``max_depth`` stored as flat
heap arrays, which keeps every shape static (level-wise growth, the
XGBoost/LightGBM histogram method). A node that should not split gets the
sentinel threshold ``+inf`` so every sample routes left and the right subtree
becomes unreachable.

Layout (per tree):
  feat   : (2**D - 1,) int32   feature index per internal heap node
  thresh : (2**D - 1,) float32 ``x <= thresh`` routes left; +inf = no split
  leaf   : (2**D, C)   float32 leaf payload (class counts, boosting weight,
                               or isolation sample count)

The IIsy mapping tool (repro.core.mapping) consumes exactly these arrays.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TreeEnsemble:
    """A bag of complete trees plus ensemble metadata."""

    feat: jax.Array        # (T, 2**D - 1) int32
    thresh: jax.Array      # (T, 2**D - 1) float32
    leaf: jax.Array        # (T, 2**D, C) float32
    kind: str = dataclasses.field(metadata=dict(static=True), default="rf")
    # 'dt' | 'rf' | 'xgb' | 'iforest'
    base_score: float = dataclasses.field(metadata=dict(static=True), default=0.0)
    learning_rate: float = dataclasses.field(metadata=dict(static=True), default=1.0)
    n_classes: int = dataclasses.field(metadata=dict(static=True), default=2)

    @property
    def n_trees(self) -> int:
        return self.feat.shape[0]

    @property
    def depth(self) -> int:
        return int(np.log2(self.feat.shape[1] + 1))


# ---------------------------------------------------------------------------
# binning
# ---------------------------------------------------------------------------

def quantile_bin_edges(x: jax.Array, n_bins: int) -> jax.Array:
    """Per-feature quantile bin edges. Returns (F, n_bins - 1).

    ``bin(v) = sum(v > edges)`` so the split rule ``bin <= b`` is exactly
    ``v <= edges[b]``.
    """
    qs = jnp.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = jnp.quantile(x, qs, axis=0).T  # (F, n_bins-1)
    # Strictly increasing edges are not required; duplicated edges simply
    # produce empty bins, which the split search masks out.
    return edges


def bin_data(x: jax.Array, edges: jax.Array) -> jax.Array:
    """Map raw features (N, F) onto bin ids (N, F) in [0, n_bins)."""
    return jnp.sum(x[:, :, None] > edges[None, :, :], axis=2).astype(jnp.int32)


# ---------------------------------------------------------------------------
# shared level-wise growth
# ---------------------------------------------------------------------------

def _grow_level_hist(bins, node_id, stats, n_nodes, n_feat, n_bins):
    """Scatter-add per-(node, feature, bin) statistic histograms.

    bins    : (N, F) int32
    node_id : (N,) int32 current heap-node-within-level index in [0, n_nodes)
    stats   : (N, S) float32 per-sample statistics (class one-hot or (g, h))
    returns : (n_nodes, F, n_bins, S)
    """
    n, f = bins.shape
    flat = (node_id[:, None] * n_feat + jnp.arange(n_feat)[None, :]) * n_bins + bins
    hist = jnp.zeros((n_nodes * n_feat * n_bins, stats.shape[1]), stats.dtype)
    hist = hist.at[flat].add(stats[:, None, :])
    return hist.reshape(n_nodes, n_feat, n_bins, stats.shape[1])


def _route(bins, node_id, level_feat, level_split_bin):
    """Advance samples one level down. Returns node index within next level."""
    f = level_feat[node_id]                       # (N,)
    b = jnp.take_along_axis(bins, f[:, None], axis=1)[:, 0]
    go_right = b > level_split_bin[node_id]
    return node_id * 2 + go_right.astype(jnp.int32)


def _gini_best_split(hist, min_leaf):
    """Best (feature, bin) per node from class-count histograms.

    hist: (nodes, F, B, C) counts. Returns (feat, split_bin, has_split).
    """
    left = jnp.cumsum(hist, axis=2)                     # counts left of split
    total = left[:, :, -1:, :]
    right = total - left
    n_l = left.sum(-1)                                  # (nodes, F, B)
    n_r = right.sum(-1)
    n_t = n_l + n_r

    def gini(counts, n):
        p = counts / jnp.maximum(n[..., None], 1.0)
        return 1.0 - jnp.sum(p * p, axis=-1)

    g_parent = gini(total, n_t[..., -1:])               # (nodes, F, 1)
    gain = g_parent - (n_l / jnp.maximum(n_t, 1.0)) * gini(left, n_l) \
                    - (n_r / jnp.maximum(n_t, 1.0)) * gini(right, n_r)
    valid = (n_l >= min_leaf) & (n_r >= min_leaf)
    valid = valid.at[:, :, -1].set(False)               # right side empty
    gain = jnp.where(valid, gain, NEG_INF)
    flat = gain.reshape(gain.shape[0], -1)
    best = jnp.argmax(flat, axis=1)
    n_bins = hist.shape[2]
    return best // n_bins, best % n_bins, jnp.max(flat, axis=1) > 0.0


def _xgb_best_split(hist, reg_lambda, min_child_weight, gamma=0.0):
    """Best split from (g, h) histograms. hist: (nodes, F, B, 2).

    ``gamma`` is XGBoost's min-split-gain: weak splits are pruned, which
    is the paper's §4.2 "prune trees to create action codes of feasible
    length" knob (fewer thresholds -> smaller decision tables)."""
    left = jnp.cumsum(hist, axis=2)
    total = left[:, :, -1:, :]
    right = total - left
    gl, hl = left[..., 0], left[..., 1]
    gr, hr = right[..., 0], right[..., 1]
    gt, ht = total[..., 0], total[..., 1]

    def score(g, h):
        return (g * g) / (h + reg_lambda)

    gain = 0.5 * (score(gl, hl) + score(gr, hr) - score(gt, ht))
    valid = (hl >= min_child_weight) & (hr >= min_child_weight)
    valid = valid.at[:, :, -1].set(False)
    gain = jnp.where(valid, gain, NEG_INF)
    flat = gain.reshape(gain.shape[0], -1)
    best = jnp.argmax(flat, axis=1)
    n_bins = hist.shape[2]
    return best // n_bins, best % n_bins, jnp.max(flat, axis=1) > gamma


def _fill_level(feat_heap, thresh_heap, level, level_feat, level_thresh):
    start = (1 << level) - 1
    feat_heap = jax.lax.dynamic_update_slice(feat_heap, level_feat, (start,))
    thresh_heap = jax.lax.dynamic_update_slice(thresh_heap, level_thresh, (start,))
    return feat_heap, thresh_heap


# ---------------------------------------------------------------------------
# decision tree / random forest
# ---------------------------------------------------------------------------

def _fit_one_gini_tree(bins, y1h, edges, depth, n_bins, min_leaf, feat_mask):
    """Grow one gini tree on pre-binned data. All shapes static.

    bins (N, F) int32, y1h (N, C), edges (F, n_bins-1), feat_mask (F,) bool.
    """
    n, n_feat = bins.shape
    n_heap = (1 << depth) - 1
    feat_heap = jnp.zeros((n_heap,), jnp.int32)
    thresh_heap = jnp.full((n_heap,), jnp.inf, jnp.float32)
    node_id = jnp.zeros((n,), jnp.int32)

    for level in range(depth):
        n_nodes = 1 << level
        hist = _grow_level_hist(bins, node_id, y1h, n_nodes, n_feat, n_bins)
        masked = jnp.where(feat_mask[None, :, None, None], hist,
                           jnp.zeros_like(hist))
        bf, bb, ok = _gini_best_split(masked, min_leaf)
        thr = edges[bf, jnp.minimum(bb, edges.shape[1] - 1)]
        level_feat = jnp.where(ok, bf, 0).astype(jnp.int32)
        level_thresh = jnp.where(ok, thr, jnp.inf)
        # route with the *bin* rule (bin <= bb left); unsplit nodes go left
        eff_bin = jnp.where(ok, bb, n_bins)  # everything <= n_bins-1 -> left
        node_id = _route(bins, node_id, level_feat, eff_bin)
        feat_heap, thresh_heap = _fill_level(
            feat_heap, thresh_heap, level, level_feat, level_thresh)

    # leaves: class counts
    n_leaf = 1 << depth
    leaf = jnp.zeros((n_leaf, y1h.shape[1]), jnp.float32).at[node_id].add(y1h)
    return feat_heap, thresh_heap, leaf


def fit_decision_tree(x, y, *, n_classes, max_depth=5, n_bins=64,
                      min_leaf=1.0, edges=None):
    """CART-style gini decision tree. Returns a single-tree TreeEnsemble."""
    x = jnp.asarray(x, jnp.float32)
    y1h = jax.nn.one_hot(jnp.asarray(y), n_classes, dtype=jnp.float32)
    if edges is None:
        edges = quantile_bin_edges(x, n_bins)
    bins = bin_data(x, edges)
    feat_mask = jnp.ones((x.shape[1],), bool)
    f, t, l = jax.jit(_fit_one_gini_tree, static_argnums=(3, 4))(
        bins, y1h, edges, max_depth, n_bins, min_leaf, feat_mask)
    return TreeEnsemble(feat=f[None], thresh=t[None], leaf=l[None],
                        kind="dt", n_classes=n_classes)


def fit_random_forest(x, y, *, n_classes, n_trees=10, max_depth=5, n_bins=64,
                      min_leaf=1.0, max_features=None, seed=0,
                      tree_chunk=16, edges=None):
    """Bagged gini trees (bootstrap rows + per-tree feature subsampling)."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y)
    n, n_feat = x.shape
    if max_features is None:
        max_features = max(1, int(np.sqrt(n_feat)))
    if edges is None:
        edges = quantile_bin_edges(x, n_bins)
    bins = bin_data(x, edges)
    y1h = jax.nn.one_hot(y, n_classes, dtype=jnp.float32)

    def one_tree(key):
        k_boot, k_feat = jax.random.split(key)
        idx = jax.random.randint(k_boot, (n,), 0, n)
        perm = jax.random.permutation(k_feat, n_feat)
        mask = jnp.zeros((n_feat,), bool).at[perm[:max_features]].set(True)
        return _fit_one_gini_tree(bins[idx], y1h[idx], edges,
                                  max_depth, n_bins, min_leaf, mask)

    keys = jax.random.split(jax.random.PRNGKey(seed), n_trees)
    fit_chunk = jax.jit(jax.vmap(one_tree))
    outs = [fit_chunk(keys[i:i + tree_chunk])
            for i in range(0, n_trees, tree_chunk)]
    f, t, l = (jnp.concatenate([o[j] for o in outs]) for j in range(3))
    return TreeEnsemble(feat=f, thresh=t, leaf=l, kind="rf",
                        n_classes=n_classes)


# ---------------------------------------------------------------------------
# XGBoost-style boosting (binary logistic)
# ---------------------------------------------------------------------------

def _fit_one_xgb_tree(bins, g, h, edges, depth, n_bins, reg_lambda,
                      min_child_weight, gamma=0.0):
    n, n_feat = bins.shape
    n_heap = (1 << depth) - 1
    feat_heap = jnp.zeros((n_heap,), jnp.int32)
    thresh_heap = jnp.full((n_heap,), jnp.inf, jnp.float32)
    node_id = jnp.zeros((n,), jnp.int32)
    stats = jnp.stack([g, h], axis=1)

    for level in range(depth):
        n_nodes = 1 << level
        hist = _grow_level_hist(bins, node_id, stats, n_nodes, n_feat, n_bins)
        bf, bb, ok = _xgb_best_split(hist, reg_lambda, min_child_weight,
                                     gamma)
        thr = edges[bf, jnp.minimum(bb, edges.shape[1] - 1)]
        level_feat = jnp.where(ok, bf, 0).astype(jnp.int32)
        level_thresh = jnp.where(ok, thr, jnp.inf)
        eff_bin = jnp.where(ok, bb, n_bins)
        node_id = _route(bins, node_id, level_feat, eff_bin)
        feat_heap, thresh_heap = _fill_level(
            feat_heap, thresh_heap, level, level_feat, level_thresh)

    n_leaf = 1 << depth
    g_leaf = jnp.zeros((n_leaf,), jnp.float32).at[node_id].add(g)
    h_leaf = jnp.zeros((n_leaf,), jnp.float32).at[node_id].add(h)
    w = -g_leaf / (h_leaf + reg_lambda)
    return feat_heap, thresh_heap, w[:, None], node_id


def fit_xgboost(x, y, *, n_trees=10, max_depth=4, n_bins=64,
                learning_rate=0.3, reg_lambda=1.0, min_child_weight=1.0,
                gamma=0.0, base_score=0.0, edges=None):
    """Second-order boosted trees, binary logistic objective."""
    x = jnp.asarray(x, jnp.float32)
    yf = jnp.asarray(y, jnp.float32)
    if edges is None:
        edges = quantile_bin_edges(x, n_bins)
    bins = bin_data(x, edges)

    fit_tree = jax.jit(_fit_one_xgb_tree, static_argnums=(4, 5))

    margin = jnp.full((x.shape[0],), base_score, jnp.float32)
    feats, threshs, leaves = [], [], []
    for _ in range(n_trees):
        p = jax.nn.sigmoid(margin)
        g = p - yf
        h = jnp.maximum(p * (1.0 - p), 1e-6)
        f, t, w, node_id = fit_tree(bins, g, h, edges, max_depth, n_bins,
                                    reg_lambda, min_child_weight, gamma)
        margin = margin + learning_rate * w[node_id, 0]
        feats.append(f); threshs.append(t); leaves.append(w)
    return TreeEnsemble(feat=jnp.stack(feats), thresh=jnp.stack(threshs),
                        leaf=jnp.stack(leaves), kind="xgb",
                        base_score=base_score, learning_rate=learning_rate,
                        n_classes=2)


# ---------------------------------------------------------------------------
# Isolation forest
# ---------------------------------------------------------------------------

def _fit_one_iso_tree(bins, edges, depth, n_bins, key):
    n, n_feat = bins.shape
    n_heap = (1 << depth) - 1
    feat_heap = jnp.zeros((n_heap,), jnp.int32)
    thresh_heap = jnp.full((n_heap,), jnp.inf, jnp.float32)
    node_id = jnp.zeros((n,), jnp.int32)
    ones = jnp.ones((n, 1), jnp.float32)

    for level in range(depth):
        n_nodes = 1 << level
        key, k_f, k_b = jax.random.split(key, 3)
        hist = _grow_level_hist(bins, node_id, ones, n_nodes, n_feat,
                                n_bins)[..., 0]               # (nodes, F, B)
        level_feat = jax.random.randint(k_f, (n_nodes,), 0, n_feat)
        h_f = jnp.take_along_axis(
            hist, level_feat[:, None, None], axis=1)[:, 0, :]  # (nodes, B)
        present = h_f > 0
        lo = jnp.argmax(present, axis=1)
        hi = n_bins - 1 - jnp.argmax(present[:, ::-1], axis=1)
        u = jax.random.uniform(k_b, (n_nodes,))
        bb = (lo + (u * jnp.maximum(hi - lo, 0)).astype(jnp.int32))
        bb = jnp.clip(bb, 0, n_bins - 2)
        splittable = hi > lo
        thr = edges[level_feat, jnp.minimum(bb, edges.shape[1] - 1)]
        level_thresh = jnp.where(splittable, thr, jnp.inf)
        eff_bin = jnp.where(splittable, bb, n_bins)
        node_id = _route(bins, node_id, jnp.where(splittable, level_feat, 0),
                         eff_bin)
        feat_heap, thresh_heap = _fill_level(
            feat_heap, thresh_heap, level,
            jnp.where(splittable, level_feat, 0).astype(jnp.int32),
            level_thresh)

    n_leaf = 1 << depth
    count = jnp.zeros((n_leaf, 1), jnp.float32).at[node_id].add(ones)
    return feat_heap, thresh_heap, count


def fit_isolation_forest(x, *, n_trees=32, max_depth=6, n_bins=64,
                         subsample=256, seed=0, edges=None):
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    if edges is None:
        edges = quantile_bin_edges(x, n_bins)
    bins_full = bin_data(x, edges)
    sub = min(subsample, n)

    def one_tree(key):
        k_s, k_t = jax.random.split(key)
        idx = jax.random.choice(k_s, n, (sub,), replace=False)
        return _fit_one_iso_tree(bins_full[idx], edges, max_depth, n_bins, k_t)

    keys = jax.random.split(jax.random.PRNGKey(seed), n_trees)
    f, t, l = jax.jit(jax.vmap(one_tree))(keys)
    return TreeEnsemble(feat=f, thresh=t, leaf=l, kind="iforest", n_classes=2)


# ---------------------------------------------------------------------------
# prediction
# ---------------------------------------------------------------------------

def _leaf_index(feat, thresh, x, depth):
    """Heap walk, fixed depth. x: (N, F); feat/thresh: (H,). -> (N,) leaf id."""
    n = x.shape[0]
    node = jnp.zeros((n,), jnp.int32)
    for _ in range(depth):
        f = feat[node]
        t = thresh[node]
        xv = jnp.take_along_axis(x, f[:, None], axis=1)[:, 0]
        node = 2 * node + 1 + (xv > t).astype(jnp.int32)
    return node - ((1 << depth) - 1)


def tree_leaf_indices(ens: TreeEnsemble, x) -> jax.Array:
    """(T, N) leaf index per tree."""
    x = jnp.asarray(x, jnp.float32)
    depth = ens.depth
    return jax.vmap(lambda f, t: _leaf_index(f, t, x, depth))(ens.feat,
                                                              ens.thresh)


def predict_proba_tree_ensemble(ens: TreeEnsemble, x) -> jax.Array:
    """Mean per-tree class distribution (DT/RF). -> (N, C)."""
    leaf_idx = tree_leaf_indices(ens, x)               # (T, N)
    counts = jnp.take_along_axis(
        ens.leaf, leaf_idx[:, :, None], axis=1)        # (T, N, C)
    probs = counts / jnp.maximum(counts.sum(-1, keepdims=True), 1e-9)
    return probs.mean(axis=0)


def predict_margin_xgboost(ens: TreeEnsemble, x) -> jax.Array:
    leaf_idx = tree_leaf_indices(ens, x)
    w = jnp.take_along_axis(ens.leaf[..., 0], leaf_idx, axis=1)  # (T, N)
    return ens.base_score + ens.learning_rate * w.sum(axis=0)


def _c_factor(n):
    n = jnp.maximum(n, 2.0)
    return 2.0 * (jnp.log(n - 1.0) + 0.5772156649) - 2.0 * (n - 1.0) / n


def predict_iforest_score(ens: TreeEnsemble, x, subsample=256) -> jax.Array:
    """Anomaly score in (0, 1); higher = more anomalous."""
    leaf_idx = tree_leaf_indices(ens, x)
    size = jnp.take_along_axis(ens.leaf[..., 0], leaf_idx, axis=1)
    depth = ens.depth
    path = depth + jnp.where(size > 1, _c_factor(size), 0.0)
    e_path = path.mean(axis=0)
    return 2.0 ** (-e_path / _c_factor(jnp.float32(subsample)))


def predict_tree_ensemble(ens: TreeEnsemble, x) -> jax.Array:
    """Hard class prediction for any tree kind."""
    if ens.kind in ("dt", "rf"):
        return jnp.argmax(predict_proba_tree_ensemble(ens, x), axis=1)
    if ens.kind == "xgb":
        return (predict_margin_xgboost(ens, x) > 0.0).astype(jnp.int32)
    if ens.kind == "iforest":
        return (predict_iforest_score(ens, x) > 0.5).astype(jnp.int32)
    raise ValueError(ens.kind)
