"""JAX-native classical-ML training substrate.

IIsy's prototype trains with scikit-learn; this package is the equivalent
substrate built in JAX so the whole framework is self-contained: histogram
decision trees / random forests / gradient boosting / isolation forests,
linear SVM, Gaussian naive Bayes and K-means — all with fixed-shape,
jit-compatible training loops, plus the metrics used in the paper's tables.
"""

from repro.ml.trees import (
    TreeEnsemble,
    fit_decision_tree,
    fit_random_forest,
    fit_xgboost,
    fit_isolation_forest,
    predict_tree_ensemble,
    predict_proba_tree_ensemble,
    predict_margin_xgboost,
    predict_iforest_score,
    quantile_bin_edges,
)
from repro.ml.svm import LinearSVM, fit_linear_svm, predict_svm, svm_decision_values
from repro.ml.naive_bayes import GaussianNB, fit_gaussian_nb, predict_nb, nb_log_likelihood
from repro.ml.kmeans import KMeansModel, fit_kmeans, predict_kmeans
from repro.ml.metrics import (
    accuracy,
    precision_recall_f1,
    confusion_matrix,
    macro_f1,
)
