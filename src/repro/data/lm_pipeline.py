"""Deterministic, sharded LM token pipeline.

Fault-tolerance property: batch(step, shard) is a pure function of
(seed, step, shard) — any host can recompute any shard's data after a
failover, so checkpoint/restart never loses or duplicates samples and no
data-state needs checkpointing beyond the step counter. This is the
standard design for 1000+-node determinism (cf. MaxText's grain indices).

Source: a synthetic Zipf-distributed token stream with a Markov flavor so
a real LM loss signal exists (perplexity decreases under training), plus a
double-buffered host prefetcher to overlap host data generation with device
steps (straggler mitigation at the input layer).
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, n_shards: int = 1, shard: int = 0, seed: int = 0):
        assert global_batch % n_shards == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.local_batch = global_batch // n_shards
        self.n_shards = n_shards
        self.shard = shard
        self.seed = seed
        # Zipf-ish unigram with Markov "bigram bonus" for learnable structure
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks ** 1.1)
        self._unigram /= self._unigram.sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step, shard)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        b, s, v = self.local_batch, self.seq_len, self.vocab_size
        base = rng.choice(v, size=(b, s + 1), p=self._unigram)
        # Markov structure: with p=0.5 the next token is a deterministic
        # function of the previous one -> learnable signal
        follow = (base[:, :-1] * 7 + 11) % v
        mask = rng.random((b, s)) < 0.5
        tokens = base[:, :-1].copy()
        labels = np.where(mask, follow, base[:, 1:])
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def prefetch(self, start_step: int, depth: int = 2):
        """Background-thread prefetch iterator (double buffering)."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch(step)))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()

        class _Iter:
            def __iter__(self):
                return self

            def __next__(self):
                return q.get()

            def close(self):
                stop.set()
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass

        return _Iter()
