"""Synthetic stand-in for the Jane Street Market Prediction dataset.

The real dataset: 130 anonymized numeric features per trade and two return
values ('weight', 'resp'); the paper labels trades 'strong sell/buy' (~13.1 %)
vs 'sell/hold/buy' and reports error rates around 0.23-0.26 — i.e. a *hard*,
low-signal task. This generator reproduces that regime: 130 correlated
Gaussian-ish features with a weak nonlinear signal in a small subset
(including indices 42, 43, 45, 124, 126 — the features the paper extracts on
the switch), plus heavy noise so that even large models plateau well below
perfect accuracy.
"""

from __future__ import annotations

import numpy as np

N_FEATURES = 130
N_CLASSES = 2  # 1 = strong sell/buy (the time-sensitive minority class)
SWITCH_FEATURES = [42, 43, 45, 124, 126]  # §7.2 of the paper


def make_janestreet_like(n=20000, positive_frac=0.131, seed=0):
    rng = np.random.default_rng(seed)
    # correlated feature panel: low-rank structure + idiosyncratic noise
    k = 12
    loadings = rng.normal(0, 1, (k, N_FEATURES))
    factors = rng.normal(0, 1, (n, k))
    x = factors @ loadings + rng.normal(0, 1.5, (n, N_FEATURES))

    # weak nonlinear signal on a sparse subset (incl. the switch features)
    sig_idx = np.array(SWITCH_FEATURES + [7, 13, 64, 99])
    s = x[:, sig_idx]
    score = (0.9 * s[:, 0] - 0.7 * s[:, 1] + 0.5 * np.tanh(s[:, 2])
             + 0.6 * s[:, 3] * (s[:, 4] > 0) + 0.3 * s[:, 5]
             - 0.4 * np.abs(s[:, 6]) + 0.25 * s[:, 7] * s[:, 8])
    score = score + rng.normal(0, 2.6, n)        # SNR tuned for ~0.23+ error
    thr = np.quantile(score, 1.0 - positive_frac)
    y = (score > thr).astype(np.int32)
    return x.astype(np.float32), y


def train_test_split(x, y, test_frac=0.2, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n_test = int(len(x) * test_frac)
    te, tr = idx[:n_test], idx[n_test:]
    return x[tr], y[tr], x[te], y[te]
