from repro.data.unsw_like import make_unsw_like
from repro.data.janestreet_like import make_janestreet_like
