"""Synthetic stand-in for the UNSW-NB15 anomaly-detection dataset.

UNSW-NB15 is not redistributable offline; this generator reproduces the
*statistical shape* the paper relies on: flow records with packet-level
features (ports, protocol, service, port-equality flag) plus flow-level
features (duration, bytes/packets in both directions), heavily biased toward
normal traffic (~87 % normal / 13 % attack), where attacks shift the feature
distributions enough that a small tree ensemble reaches high accuracy but a
large one is measurably better — matching Table 3's regime.

Feature order (matches the paper's resource study; first five are the
Table 1 feature set):
  0 sport  1 dsport  2 proto  3 service  4 is_sm_ips_ports
  5 dur    6 sbytes  7 dbytes  8 spkts   9 dpkts
"""

from __future__ import annotations

import numpy as np

FEATURE_NAMES = [
    "sport", "dsport", "proto", "service", "is_sm_ips_ports",
    "dur", "sbytes", "dbytes", "spkts", "dpkts",
]

N_CLASSES = 2  # 0 = normal, 1 = anomaly


def make_unsw_like(n=20000, anomaly_frac=0.13, seed=0, n_features=10):
    """Returns (x, y) float32/int32 numpy arrays, x: (n, n_features)."""
    rng = np.random.default_rng(seed)
    y = (rng.random(n) < anomaly_frac).astype(np.int32)
    n_anom = int(y.sum())
    x = np.zeros((n, 10), np.float32)

    normal = y == 0
    anom = y == 1

    # sport: ephemeral for normal clients; attacks reuse low/fixed ports
    x[normal, 0] = rng.integers(32768, 61000, normal.sum())
    x[anom, 0] = np.where(rng.random(n_anom) < 0.6,
                          rng.integers(1024, 5000, n_anom),
                          rng.integers(32768, 61000, n_anom))
    # dsport: normal -> web/dns-ish {80,443,53,22}; attacks scan wide
    common = np.array([80, 443, 53, 22, 25])
    x[normal, 1] = common[rng.integers(0, len(common), normal.sum())]
    x[anom, 1] = np.where(rng.random(n_anom) < 0.7,
                          rng.integers(1, 10000, n_anom),
                          common[rng.integers(0, len(common), n_anom)])
    # proto: 6=tcp 17=udp 1=icmp; attacks over-use udp/icmp
    x[normal, 2] = rng.choice([6, 17, 1], normal.sum(), p=[0.8, 0.18, 0.02])
    x[anom, 2] = rng.choice([6, 17, 1], n_anom, p=[0.45, 0.35, 0.2])
    # service code 0..12
    x[normal, 3] = rng.choice(13, normal.sum(),
                              p=np.array([30, 25, 15, 10, 5, 4, 3, 3, 2, 1, 1, 0.5, 0.5]) / 100)
    x[anom, 3] = rng.choice(13, n_anom,
                            p=np.array([5, 5, 5, 5, 10, 10, 10, 10, 10, 10, 10, 5, 5]) / 100)
    # is_sm_ips_ports: rarely 1 for normal, more for spoofed attack flows
    x[normal, 4] = (rng.random(normal.sum()) < 0.01).astype(np.float32)
    x[anom, 4] = (rng.random(n_anom) < 0.25).astype(np.float32)
    # dur (s): lognormal; attacks shorter (scans) or much longer (dos)
    x[normal, 5] = rng.lognormal(-1.0, 1.0, normal.sum())
    x[anom, 5] = np.where(rng.random(n_anom) < 0.7,
                          rng.lognormal(-3.5, 0.8, n_anom),
                          rng.lognormal(2.0, 1.0, n_anom))
    # sbytes / dbytes: attacks send more, receive less
    x[normal, 6] = rng.lognormal(6.0, 1.2, normal.sum())
    x[anom, 6] = rng.lognormal(7.5, 1.5, n_anom)
    x[normal, 7] = rng.lognormal(7.0, 1.4, normal.sum())
    x[anom, 7] = rng.lognormal(4.0, 1.5, n_anom)
    # spkts / dpkts correlated with bytes
    x[:, 8] = np.maximum(x[:, 6] / rng.lognormal(6.0, 0.3, n), 1.0)
    x[:, 9] = np.maximum(x[:, 7] / rng.lognormal(6.0, 0.3, n), 1.0)

    # label noise so even the big backend cannot be perfect (paper: 99.5 %)
    flip = rng.random(n) < 0.004
    y = np.where(flip, 1 - y, y)
    return x[:, :n_features].astype(np.float32), y.astype(np.int32)


def train_test_split(x, y, test_frac=0.2, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n_test = int(len(x) * test_frac)
    te, tr = idx[:n_test], idx[n_test:]
    return x[tr], y[tr], x[te], y[te]
