"""Three-term roofline from the compiled (SPMD-partitioned, per-device) HLO.

  compute    = flops_per_device / peak_flops          (MXU-bound time)
  memory     = bytes_per_device / hbm_bw              (HBM-bound time)
  collective = ici_bytes_per_device / link_bw         (ICI-bound time)

flops / bytes come from ``compiled.cost_analysis()`` (per-device, since the
compiled module is the per-device SPMD program). Collective bytes are NOT in
cost_analysis: we parse the optimized HLO text and sum wire bytes per
collective with ring-algorithm multipliers over the op's replica-group size G:

  all-gather         (G-1)/G * result_bytes
  all-reduce       2*(G-1)/G * result_bytes
  reduce-scatter     (G-1)   * result_bytes     (operand = G * result)
  all-to-all         (G-1)/G * result_bytes
  collective-permute          result_bytes

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

HW = {
    "peak_flops": 197e12,    # bf16 / chip
    "hbm_bw": 819e9,         # bytes/s / chip
    "ici_bw": 50e9,          # bytes/s / link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(result_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [n_groups, group_size]<=[total]
        return max(1, int(m.group(2)))
    return 1


def _wire_multiplier(op: str, g: int) -> float:
    if op == "collective-permute":     # pairs, not groups: always moves data
        return 1.0
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return (g - 1) / g
    if op == "all-reduce":
        return 2 * (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """-> {"total": wire bytes/device, "by_op": {...}, "count": int}."""
    by_op: dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result = m.group("result")
        g = _group_size(line)
        wire = _shape_bytes(result) * _wire_multiplier(op, g)
        by_op[op] = by_op.get(op, 0.0) + wire
        count += 1
    return {"total": sum(by_op.values()), "by_op": by_op, "count": count}


def roofline_terms(cost: dict, coll: dict, *, hw: dict = HW) -> dict:
    """Seconds per step for each roofline term + the dominant one."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    bytes_ici = float(coll["total"])
    terms = {
        "compute_s": flops / hw["peak_flops"],
        "memory_s": bytes_hbm / hw["hbm_bw"],
        "collective_s": bytes_ici / hw["ici_bw"],
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {**terms, "dominant": dominant,
            "flops_per_dev": flops, "hbm_bytes_per_dev": bytes_hbm,
            "ici_bytes_per_dev": bytes_ici,
            # fraction of ideal: if perfectly overlapped, step time = max term
            "overlap_roofline_frac": bound / total if total > 0 else 0.0}


def model_flops(cfg, n_params_total: int, n_params_active: int,
                shape_kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params.

    D = processed tokens: seq*batch for train/prefill, batch for decode."""
    n = n_params_active
    if shape_kind == "train":
        return 6.0 * n * seq_len * global_batch
    if shape_kind == "prefill":
        return 2.0 * n * seq_len * global_batch
    return 2.0 * n * global_batch          # decode: one token per request
