"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSON records emitted by launch.dryrun.

    PYTHONPATH=src python -m repro.roofline.report artifacts/dryrun
"""

from __future__ import annotations

import json
import os
import sys

from repro.roofline.analysis import HW

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(d, refresh_analytic=True):
    recs = {}
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        if refresh_analytic and "skipped" not in r:
            _refresh(r)
        recs[fn[:-5]] = r
    return recs


def _refresh(r):
    """Recompute analytic flops/bytes terms with the current analytic
    model (decoupled from the sweep: the stored collective correction —
    the expensive part — stays)."""
    try:
        from repro.configs import get_config
        from repro.models import model as M
        from repro.roofline.analysis import roofline_terms
        from repro.roofline.analytic import (cell_flops_per_device,
                                             cell_hbm_bytes_per_device,
                                             decode_cache_bytes)
        cfg = get_config(r["arch"])
        n_chips = r["chips"]
        an_flops = cell_flops_per_device(cfg, r["shape"], n_chips,
                                         remat=r.get("remat", True))
        cache_b = (decode_cache_bytes(cfg, r["shape"],
                                      int8_kv=r.get("int8_kv", False))
                   if r["kind"] == "decode" else 0)
        an_bytes = cell_hbm_bytes_per_device(
            cfg, r["shape"], n_chips, r["params_total"], cache_b,
            remat=r.get("remat", True))
        coll = (r.get("collective_bytes_corrected")
                or r.get("collectives", {}).get("total", 0.0))
        roof = roofline_terms({"flops": an_flops,
                               "bytes accessed": an_bytes},
                              {"total": coll})
        r["roofline"] = {k: roof[k] for k in
                         ("compute_s", "memory_s", "collective_s",
                          "dominant", "overlap_roofline_frac")}
        r["analytic"] = {"flops_per_dev": an_flops,
                         "hbm_bytes_per_dev": an_bytes}
        mf = r.get("model_flops_global")
        if mf:
            r["useful_flops_ratio"] = mf / (an_flops * n_chips)
    except (KeyError, TypeError, ValueError, ZeroDivisionError):
        # roofline augmentation is best-effort decoration of a report
        # row: malformed/partial rows keep their measured fields
        pass


def _fmt_bytes(b):
    if b >= 1 << 30:
        return f"{b / (1 << 30):.1f}G"
    return f"{b / (1 << 20):.0f}M"


def _improvement_hint(r):
    d = r["roofline"]["dominant"]
    kind = r["kind"]
    if d == "collective_s":
        if kind == "train":
            return ("bf16 FSDP gathers / grad compression would halve the "
                    "dominant DP+TP collective bytes")
        return "replicate small weights (skip TP gathers) for this step"
    if d == "memory_s":
        if kind != "train":
            return ("KV/state cache reads dominate; quantized (int8) cache "
                    "or wider batch amortizes weight reads")
        return "activation remat policy / microbatching trades HBM for FLOPs"
    return "MoE/attn FLOPs dominate; better — push batch or drop remat"


def render(recs, mesh_tag="16x16"):
    lines = []
    lines.append(f"\n### Roofline table — mesh {mesh_tag} "
                 f"(per-chip: {HW['peak_flops'] / 1e12:.0f} TFLOP/s bf16, "
                 f"{HW['hbm_bw'] / 1e9:.0f} GB/s HBM, "
                 f"{HW['ici_bw'] / 1e9:.0f} GB/s/link ICI)\n")
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | peak B/dev | useful FLOPs | note |")
    lines.append(hdr)
    lines.append("|" + "---|" * 9)
    for key, r in sorted(recs.items()):
        if not key.endswith("__" + mesh_tag):
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"skipped | - | - | {r['skipped']} |")
            continue
        roof = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {roof['compute_s']:.4g} | {roof['memory_s']:.4g} "
            f"| {roof['collective_s']:.4g} "
            f"| {roof['dominant'].replace('_s', '')} "
            f"| {_fmt_bytes(r['memory']['peak_per_device'])} "
            f"| {r['useful_flops_ratio'] * 100:.0f}% "
            f"| {_improvement_hint(r)} |")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    recs = load_records(d)
    print(f"{len(recs)} records from {d}")
    print(render(recs, "16x16"))
    print(render(recs, "2x16x16"))


if __name__ == "__main__":
    main()
