"""Exact analytic FLOPs / HBM-bytes per (arch x shape) cell.

Why this exists: ``compiled.cost_analysis()`` visits each ``lax.scan``
body ONCE — flops/bytes inside the layer scan (and the blockwise-
attention inner loops) are undercounted by the trip count (verified
empirically; see EXPERIMENTS.md §Methodology). The architecture is ours,
so the exact counts are computable in closed form. The HLO numbers are
still recorded as a secondary signal.

Counting conventions:
  * matmul flops = 2*M*N*K; backward = 2x forward; full remat adds +1x
    forward recompute (policy 'full') -> train multiplier 3 (+1 embed-
    free forward under remat) vs no-remat 3.
  * attention: blockwise/causal scores+AV counted exactly:
    full causal ~ S^2 (masked half still computed in dense blocks ->
    count full S*S per the kernel's actual work), windowed ~ S*W.
  * HBM bytes: params touched (fwd + bwd re-gather + optimizer state
    read/write for train), activations streamed once per op in/out at
    dtype width, KV/state caches read+write per decode step.
    This is a lower-bound streaming model — fusion-dependent temporaries
    are excluded, so the memory term is optimistic-but-consistent.
"""

from __future__ import annotations

import dataclasses
import math

from repro.launch.shapes import SHAPES
from repro.models.transformer import layer_plan, _layer_spec

BF16 = 2
F32 = 4


def _attn_flops(cfg, s_q, s_kv, batch, window=None):
    """Scores + AV for one layer."""
    h = cfg.n_heads
    hd = cfg.head_dim
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        vd = m.v_head_dim
    else:
        qk = vd = hd
    kv_eff = min(s_kv, window) if window else s_kv
    return 2.0 * batch * h * s_q * kv_eff * (qk + vd)


def _proj_flops(cfg, spec, tokens):
    """QKV/out + FFN projections for one layer, per token batch."""
    d = cfg.d_model
    block, ffn = spec
    fl = 0.0
    if block in ("attn", "local_attn"):
        h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        fl += 2.0 * tokens * d * (h * hd + 2 * g * hd + h * hd)
    elif block == "mla":
        m = cfg.mla
        h = cfg.n_heads
        fl += 2.0 * tokens * (
            d * m.q_lora_rank
            + m.q_lora_rank * h * (m.qk_nope_dim + m.qk_rope_dim)
            + d * (m.kv_lora_rank + m.qk_rope_dim)
            + m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
            + h * m.v_head_dim * d)
    elif block == "rglru":
        w = cfg.rglru_width or d
        fl += 2.0 * tokens * (2 * d * w + 2 * w * w + w * d)
    elif block == "mlstm":
        w = 2 * d
        hd = w // cfg.n_heads
        fl += 2.0 * tokens * (2 * d * w + 3 * w * hd + w * d)
        fl += 2.0 * tokens * cfg.n_heads * hd * hd * 2   # C update + read
    elif block == "slstm":
        fl += 2.0 * tokens * (d * 4 * d + d * 4 * (d // cfg.n_heads))
        fl += 2.0 * tokens * (2 * d * int(d * 4 / 3) + int(d * 4 / 3) * d)

    if ffn == "dense":
        fl += 2.0 * tokens * 3 * d * cfg.d_ff
    elif ffn == "moe":
        m = cfg.moe
        fl += 2.0 * tokens * d * m.n_experts              # router
        fl += 2.0 * tokens * m.top_k * m.capacity_factor * 3 * d * m.d_expert
        if m.n_shared:
            fl += 2.0 * tokens * 3 * d * m.d_expert * m.n_shared
        if m.dense_residual:
            fl += 2.0 * tokens * 3 * d * m.dense_d_ff
    return fl


def _param_bytes(cfg, n_params, dtype=F32):
    return n_params * dtype


def forward_flops(cfg, seq_len, batch, *, kv_len=None, decode=False):
    """One forward pass (all layers + head)."""
    tokens = batch * (1 if decode else seq_len)
    s_q = 1 if decode else seq_len
    s_kv = kv_len if kv_len is not None else seq_len
    total = 0.0
    for i in range(cfg.n_layers):
        spec = _layer_spec(cfg, i)
        total += _proj_flops(cfg, spec, tokens)
        block = spec[0]
        if block in ("attn", "local_attn", "mla"):
            window = (cfg.local_window if block == "local_attn"
                      else cfg.sliding_window)
            total += _attn_flops(cfg, s_q, s_kv, batch, window)
    if cfg.encdec:
        if not decode:
            # encoder + per-decoder-layer cross-KV projection (prefill only;
            # decode reuses the cached encoder states and cross-KV)
            enc_t = batch * cfg.n_frontend_tokens
            for _ in range(cfg.n_encoder_layers):
                total += 2.0 * enc_t * 4 * cfg.d_model * cfg.d_model
                total += 2.0 * enc_t * 2 * cfg.d_model * cfg.d_ff
                total += _attn_flops(cfg, cfg.n_frontend_tokens,
                                     cfg.n_frontend_tokens, batch)
            total += cfg.n_layers * (
                2.0 * batch * cfg.n_frontend_tokens * 2 * cfg.d_model ** 2)
        # cross-attention scores/AV every step
        total += cfg.n_layers * _attn_flops(cfg, s_q,
                                            cfg.n_frontend_tokens, batch)
    total += 2.0 * tokens * cfg.d_model * cfg.vocab_size     # head
    return total


def cell_flops_per_device(cfg, shape_name, n_chips, *, remat=True):
    spec = SHAPES[shape_name]
    b, s = spec["global_batch"], spec["seq_len"]
    if spec["kind"] == "train":
        f = forward_flops(cfg, s, b)
        mult = 3.0 + (1.0 if remat else 0.0)     # fwd + 2x bwd (+ remat)
        if cfg.mtp:
            f *= 1.0 + 1.0 / max(cfg.n_layers, 1)
        return f * mult / n_chips
    if spec["kind"] == "prefill":
        return forward_flops(cfg, s, b) / n_chips
    return forward_flops(cfg, s, b, kv_len=s, decode=True) / n_chips


def cell_hbm_bytes_per_device(cfg, shape_name, n_chips, n_params,
                              cache_bytes_total=0, *, remat=True,
                              model_shards=16):
    """Streaming lower bound: weights + activations + caches + opt state.

    Weight *compute* reads divide by the TP (model) axis only: after the
    FSDP all-gather each device holds and reads 1/model_shards of every
    layer. Optimizer-state traffic stays fully sharded (1/n_chips).
    Activations/caches are batch(+seq)-sharded: 1/n_chips.
    """
    spec = SHAPES[shape_name]
    b, s = spec["global_batch"], spec["seq_len"]
    d = cfg.d_model
    if spec["kind"] == "train":
        tokens = b * s
        # fwd + bwd weight reads (+ remat re-read) happen post-gather
        reads = 2 + (1 if remat else 0)
        w_compute = n_params * F32 * reads / model_shards
        # grads write + adam m/v read+write + param read/write: sharded
        w_opt = n_params * (F32 + 4 * F32 + 2 * F32) / n_chips
        # activations: ~14 streams/layer of (tokens, d) at bf16 + logits f32
        act = tokens * d * BF16 * 14 * cfg.n_layers / n_chips
        logits = tokens * cfg.vocab_size * F32 * 2 / n_chips
        return w_compute + w_opt + act + logits
    if spec["kind"] == "prefill":
        tokens = b * s
        w = n_params * BF16 / model_shards
        act = tokens * d * BF16 * 10 * cfg.n_layers / n_chips
        return w + act
    # decode: weights + full cache read + one slot write
    w = n_params * BF16 / model_shards
    return w + cache_bytes_total / n_chips


def decode_cache_bytes(cfg, shape_name, *, int8_kv=False):
    """Total decode-cache bytes for the cell, from the real shapes."""
    import jax
    import jax.numpy as jnp
    from repro.models import model as M
    spec = SHAPES[shape_name]
    shapes = jax.eval_shape(lambda: M.init_decode_cache(
        cfg, spec["global_batch"], spec["seq_len"], dtype=jnp.bfloat16,
        quantize_kv=int8_kv))
    return sum(math.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree.leaves(shapes))
