"""Structured event bus: JSON-lines lifecycle events for the serving loop.

The serving tiers narrate their host-side lifecycle — cuts admitted,
chunks dispatched, flushes issued and back-patched, circuit-breaker
transitions, eviction sweeps, autotune decisions, degradations — as
``Event`` records on an ``EventBus``. Everything here is HOST-side by
construction: an event is emitted around a device dispatch, never inside
one, so the donated megastep stays zero-sync and observability-off is
bit-identical to pre-observability serving (the ``BENCH_obs.json``
oracle).

Design points:

* **monotonic timestamps** — ``ts`` is ``time.monotonic()`` (injectable
  for tests), never wall-clock, so event ordering survives NTP steps and
  intervals are meaningful;
* **bounded memory** — the in-memory buffer is a ring
  (``max_events``); an open-ended stream cannot turn its own telemetry
  into a leak (the same discipline as the ingest ring and the latency
  reservoir). ``seq`` is a monotone counter, so dropped-from-the-ring
  events remain detectable;
* **JSON-lines sink** — ``JsonlSink`` appends one self-describing JSON
  object per event; ``validate_event_log`` checks a written log against
  the schema below (the CI quick run does), so downstream consumers can
  key on the contract.

Event line schema (DESIGN.md §14):

    {"v": 1, "seq": <int>, "ts": <float monotonic s>, "kind": <str>,
     ...flat JSON-safe fields...}

``kind`` must be one of ``EVENT_KINDS``; field values must be JSON
scalars (str/int/float/bool/None) or flat lists of scalars.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Callable, Iterable, Optional

EVENT_SCHEMA_VERSION = 1

# the lifecycle vocabulary: every emitter uses one of these (validated)
EVENT_KINDS = (
    # ingest / serving lifecycle
    "serve_begin",       # serve_stream entered (tier, window, chunking)
    "serve_end",         # serve_stream finished (packets, cuts, walltime)
    "cut",               # ring cut admitted (kind, packets, windows)
    "chunk",             # chunk dispatched into the megastep
    "window",            # window dispatched on the per-window path
    # backend flush lifecycle
    "flush",             # deferred-cycle flush issued (windows, trigger)
    "backpatch",         # flush answers back-patched into pending windows
    "degraded",          # a flush ultimately failed; switch answers kept
    # fault-policy guard / circuit breaker (serving.faults.GuardedBackend)
    "backend_attempt",   # one guarded backend invocation attempt
    "backend_timeout",   # an attempt was abandoned on timeout
    "backend_error",     # an attempt raised (non-timeout)
    "backend_retry",     # a retry is about to run (after backoff)
    "flush_ok",          # the flush was ultimately served
    "flush_failed",      # the flush ultimately failed (caller degrades)
    "flush_rejected",    # short-circuited by an OPEN breaker
    "breaker_open",      # CLOSED/HALF_OPEN -> OPEN
    "breaker_half_open", # OPEN -> HALF_OPEN (single probe follows)
    "breaker_close",     # HALF_OPEN -> CLOSED
    "guard_reset",       # GuardedBackend.reset() (new stream epoch)
    # lifecycle / control-plane
    "eviction",          # an aging/LRU sweep recycled buckets (rollup-rate)
    "autotune",          # a measured-sweep decision (chunk K, tiles)
    "rollup",            # a metrics rollup window closed
    "drift_alarm",       # a drift monitor fired (obs/drift.py)
)

_KIND_SET = frozenset(EVENT_KINDS)

# reserved top-level keys an emitter's fields may not shadow
_RESERVED = frozenset(("v", "seq", "ts", "kind"))


@dataclasses.dataclass(frozen=True)
class Event:
    """One structured lifecycle event (host-side, monotonic-timestamped)."""
    seq: int
    ts: float
    kind: str
    fields: dict

    def as_line(self) -> dict:
        """The flat JSON-lines form (schema above)."""
        return {"v": EVENT_SCHEMA_VERSION, "seq": self.seq, "ts": self.ts,
                "kind": self.kind, **self.fields}


class EventSchemaError(ValueError):
    """An event (or a serialized event line) violates the schema."""


def _check_field_value(key, value, where):
    ok_scalar = isinstance(value, (str, int, float, bool)) or value is None
    if ok_scalar:
        return
    if isinstance(value, (list, tuple)):
        for v in value:
            if not (isinstance(v, (str, int, float, bool)) or v is None):
                raise EventSchemaError(
                    f"{where}: field {key!r} list holds non-scalar "
                    f"{type(v).__name__}")
        return
    raise EventSchemaError(f"{where}: field {key!r} must be a JSON scalar "
                           f"or flat list, got {type(value).__name__}")


def validate_event_line(obj, where: str = "<event>") -> None:
    """Raise EventSchemaError unless ``obj`` is a valid event line."""
    if not isinstance(obj, dict):
        raise EventSchemaError(
            f"{where}: event line must be an object, "
            f"got {type(obj).__name__}")
    for key, types in (("v", int), ("seq", int), ("ts", (int, float)),
                       ("kind", str)):
        if key not in obj:
            raise EventSchemaError(f"{where}: missing key {key!r}")
        if not isinstance(obj[key], types) or isinstance(obj[key], bool):
            raise EventSchemaError(
                f"{where}: {key!r} must be {types}, "
                f"got {type(obj[key]).__name__}")
    if obj["v"] != EVENT_SCHEMA_VERSION:
        raise EventSchemaError(f"{where}: schema version must be "
                               f"{EVENT_SCHEMA_VERSION}, got {obj['v']}")
    if obj["kind"] not in _KIND_SET:
        raise EventSchemaError(f"{where}: unknown kind {obj['kind']!r}")
    for key, value in obj.items():
        if key in _RESERVED:
            continue
        _check_field_value(key, value, where)


def validate_event_log(path: str) -> int:
    """Validate a JSON-lines event log; returns the number of events.

    Checks every line against the schema AND that ``seq`` is strictly
    increasing (the bus contract — gaps are fine, they mark events the
    in-memory ring dropped, but reordering is a writer bug).
    """
    n = 0
    prev_seq = -1
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            where = f"{path}:{i + 1}"
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise EventSchemaError(f"{where}: not valid JSON ({e})") \
                    from e
            validate_event_line(obj, where)
            if obj["seq"] <= prev_seq:
                raise EventSchemaError(
                    f"{where}: seq {obj['seq']} not increasing "
                    f"(previous {prev_seq})")
            prev_seq = obj["seq"]
            n += 1
    return n


class JsonlSink:
    """Append events to a JSON-lines file (one object per line)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")

    def write(self, event: Event) -> None:
        json.dump(event.as_line(), self._f, separators=(",", ":"))
        self._f.write("\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class EventBus:
    """Bounded in-memory event ring with an optional JSON-lines sink.

    ``emit(kind, **fields)`` validates the kind eagerly (an unknown kind
    is a programming error at the call site, not a log-consumer
    surprise), stamps a monotonic timestamp and a monotone ``seq``, keeps
    the event in a bounded ring, and forwards it to the sink when one is
    attached. Emission is cheap (a dataclass + deque append) but not
    free — callers on the zero-sync hot path guard with
    ``if obs is not None`` so observability-off costs nothing at all.
    """

    def __init__(self, *, sink: Optional[JsonlSink] = None,
                 max_events: int = 65536,
                 clock: Callable[[], float] = time.monotonic):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self._ring: collections.deque = collections.deque(maxlen=max_events)
        self._seq = 0
        self.sink = sink
        self._clock = clock

    def emit(self, kind: str, **fields) -> Event:
        if kind not in _KIND_SET:
            raise EventSchemaError(f"unknown event kind {kind!r} "
                                   f"(EVENT_KINDS is the vocabulary)")
        bad = _RESERVED.intersection(fields)
        if bad:
            raise EventSchemaError(
                f"fields shadow reserved keys {sorted(bad)}")
        ev = Event(seq=self._seq, ts=self._clock(), kind=kind,
                   fields=fields)
        self._seq += 1
        self._ring.append(ev)
        if self.sink is not None:
            self.sink.write(ev)
        return ev

    # -- reading ------------------------------------------------------------

    @property
    def events(self) -> list:
        """Buffered events, oldest first (the ring may have dropped
        earlier ones — compare seq gaps)."""
        return list(self._ring)

    @property
    def emitted(self) -> int:
        """Total events emitted (including any dropped from the ring)."""
        return self._seq

    def __len__(self) -> int:
        return len(self._ring)

    def kinds(self) -> list:
        """The buffered kind sequence, oldest first (test helper)."""
        return [e.kind for e in self._ring]

    def of(self, *kinds: str) -> list:
        """Buffered events of the given kinds, oldest first."""
        want = set(kinds)
        return [e for e in self._ring if e.kind in want]

    def counts(self) -> dict:
        """kind -> buffered occurrence count."""
        c: dict = {}
        for e in self._ring:
            c[e.kind] = c.get(e.kind, 0) + 1
        return c

    def clear(self) -> None:
        """Drop buffered events (seq keeps counting — gaps stay visible)."""
        self._ring.clear()

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()


def iter_event_lines(events: Iterable[Event]):
    """Serialize events to their JSON-lines dict form (test helper)."""
    for e in events:
        yield e.as_line()
