"""Metrics registry: counters/gauges/histograms + keyed windowed rollups.

Two halves:

* ``MetricsRegistry`` — a flat named-metric store (counter / gauge /
  histogram) plus *sources*: callables returning the snapshot dict of an
  existing stats object. The four pre-observability telemetry objects
  (``StreamStats``, ``FaultStats``, ``IngestStats``, ``LatencyRecorder``)
  register as sources through their shared ``as_dict()``/``summary()``
  contract, so one ``snapshot()`` reports every tier's telemetry
  uniformly — the unification ISSUE 8's satellite asks for.

* ``RollupWindows`` — per-N-chunks windowed aggregation in the
  cowrieprocessor daily/weekly-rollup style: samples accumulate per
  *key* (today always ``"default"``; per-tenant rollups for ROADMAP
  item 2 drop in by keying on tenant id) and every ``every`` samples the
  window closes into one row carrying sums, the sample count, and the
  window index. Rows are bounded (``max_rows`` ring) so an open-ended
  stream cannot leak through its own rollups. The drift monitors
  (obs/drift.py) consume closed rollup rows.

Everything here is plain-python and host-side: reading a device-array
stat inside a registered source is the *source's* sync, taken only when
``snapshot()`` is called (the serving loop calls it at rollup
boundaries, never per window).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Optional

import numpy as np


class Counter:
    """Monotone event counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Streaming scalar distribution: count/sum/min/max plus a bounded
    sample ring for approximate percentiles."""

    __slots__ = ("n", "total", "min", "max", "_samples")

    def __init__(self, max_samples: int = 4096):
        self.n = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self._samples: collections.deque = collections.deque(
            maxlen=max_samples)

    def observe(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self._samples.append(v)

    def summary(self) -> dict:
        if not self.n:
            return {"n": 0, "mean": None, "min": None, "max": None,
                    "p50": None, "p95": None, "p99": None}
        s = np.fromiter(self._samples, np.float64)
        p50, p95, p99 = np.percentile(s, (50, 95, 99))
        return {"n": self.n, "mean": self.total / self.n,
                "min": self.min, "max": self.max, "p50": float(p50),
                "p95": float(p95), "p99": float(p99)}


_METRIC_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metrics + pluggable snapshot sources behind one snapshot().

    ``register_source(name, fn)`` takes any zero-arg callable returning a
    dict — the ``as_dict()`` of a stats object, a ``summary()``, a
    lambda reading live server state. ``snapshot()`` evaluates every
    source at call time, so a source bound to a server attribute that is
    replaced each step (e.g. ``lambda: srv.stats.as_dict()``) always
    reports the current value.
    """

    def __init__(self):
        self._metrics: dict = {}      # name -> (type_name, metric)
        self._sources: dict = {}      # name -> fn() -> dict

    def _get(self, name: str, type_name: str):
        hit = self._metrics.get(name)
        if hit is not None:
            if hit[0] != type_name:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{hit[0]}, requested {type_name}")
            return hit[1]
        m = _METRIC_TYPES[type_name]()
        self._metrics[name] = (type_name, m)
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram")

    def register_source(self, name: str, fn: Callable[[], dict]) -> None:
        """Attach (or replace) a named snapshot source."""
        self._sources[name] = fn

    def unregister_source(self, name: str) -> None:
        self._sources.pop(name, None)

    @property
    def source_names(self) -> tuple:
        return tuple(self._sources)

    def snapshot(self) -> dict:
        """One uniform telemetry dict: every metric and every source.

        Shape: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: summary...},
        "sources": {name: source_dict...}}``. A source that raises
        reports ``{"error": ...}`` instead of poisoning the snapshot
        (telemetry must never take the serving loop down).
        """
        out = {"counters": {}, "gauges": {}, "histograms": {},
               "sources": {}}
        for name, (tname, m) in sorted(self._metrics.items()):
            if tname == "counter":
                out["counters"][name] = m.value
            elif tname == "gauge":
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary()
        for name, fn in sorted(self._sources.items()):
            try:
                out["sources"][name] = dict(fn())
            except Exception as e:   # noqa: BLE001 — telemetry never raises
                out["sources"][name] = {"error": f"{type(e).__name__}: {e}"}
        return out


@dataclasses.dataclass
class _WindowAcc:
    """Open rollup window of one key: running sums + sample count."""
    n: int = 0
    sums: dict = dataclasses.field(default_factory=dict)
    first_seq: Optional[int] = None


class RollupWindows:
    """Keyed per-N-samples rollup aggregation (cowrieprocessor style).

    ``observe(sample, key=...)`` folds one numeric sample dict into the
    key's open window; after ``every`` samples the window *closes* into
    a row ``{"key", "window", "samples", "sums": {...}}`` appended to
    the bounded ``rows`` ring — and returned, so the caller can feed it
    straight to a drift monitor. Non-numeric sample values are dropped
    (rollups are arithmetic); list values of equal length are summed
    element-wise (class-count vectors).

    ``flush(key)`` / ``flush_all()`` close partial windows (end of
    stream); empty windows never produce rows.
    """

    def __init__(self, every: int = 8, max_rows: int = 4096):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = every
        self._acc: dict = {}              # key -> _WindowAcc
        self._windows: dict = {}          # key -> closed-window count
        self.rows: collections.deque = collections.deque(maxlen=max_rows)

    @staticmethod
    def _fold(sums: dict, sample: dict) -> None:
        for k, v in sample.items():
            if isinstance(v, bool):
                v = int(v)
            if isinstance(v, (int, float)):
                sums[k] = sums.get(k, 0) + v
            elif isinstance(v, (list, tuple, np.ndarray)):
                arr = np.asarray(v, np.float64)
                prev = sums.get(k)
                sums[k] = arr if prev is None else np.asarray(prev) + arr
            # non-numeric: dropped (rollups are arithmetic)

    def observe(self, sample: dict, key: str = "default"):
        """Fold one sample; returns the closed row when the window
        completes, else None."""
        acc = self._acc.get(key)
        if acc is None:
            acc = self._acc[key] = _WindowAcc()
        self._fold(acc.sums, sample)
        acc.n += 1
        if acc.n >= self.every:
            return self.flush(key)
        return None

    def flush(self, key: str = "default"):
        """Close the key's open window (even if partial). -> row or None."""
        acc = self._acc.pop(key, None)
        if acc is None or acc.n == 0:
            return None
        idx = self._windows.get(key, 0)
        self._windows[key] = idx + 1
        sums = {k: (np.asarray(v).tolist()
                    if isinstance(v, np.ndarray) else v)
                for k, v in acc.sums.items()}
        row = {"key": key, "window": idx, "samples": acc.n, "sums": sums}
        self.rows.append(row)
        return row

    def flush_all(self) -> list:
        return [r for r in (self.flush(k) for k in list(self._acc))
                if r is not None]

    def rows_for(self, key: str = "default") -> list:
        return [r for r in self.rows if r["key"] == key]

    @property
    def n_rows(self) -> int:
        return len(self.rows)
