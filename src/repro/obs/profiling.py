"""Per-stage timing for the serving pipeline, with sampled device sync.

JAX serving is asynchronous: ``step_chunk`` *enqueues* a megastep and
returns, so naive host timers around it measure dispatch latency, not
device work. The honest decomposition this module provides:

* ``StageTimer.stage(name)`` — wall-time a pipeline stage (ring cut,
  host pack, H2D transfer, megastep dispatch, backend flush,
  back-patch). Durations accumulate per stage with a bounded sample
  ring for percentiles; thread-safe enough for the prefetch thread
  (list/deque appends are atomic under the GIL).

* **sampled synchronization** — every ``sync_every``-th chunk (the
  knob; 0 = never, the default) the serving loop blocks until that
  chunk's predictions are device-complete inside a ``*_synced`` stage,
  so the sampled duration covers enqueue + device execution. Sampling
  bounds the pipelining cost: a sync drains the dispatch queue, which
  is exactly why it is off by default and why N trades fidelity against
  throughput. Sync changes *when* the host waits, never a value — the
  bit-identity oracle covers it.

* ``annotation(name)`` — ``jax.profiler.TraceAnnotation`` context for
  the megastep when a profiler trace is being captured (shows the
  serving loop's phases in TensorBoard/Perfetto); a null context when
  disabled so the default path stays allocation-free.

Stage vocabulary used by the serving tiers (DESIGN.md §14): ``ring_cut``
(pull source + admit + window-granular pack), ``h2d`` (HostCut ->
device PacketChunk transfer; queue wait when the prefetch thread owns
the transfer), ``megastep`` (step dispatch), ``megastep_synced``
(sampled: dispatch + device completion), ``backend_flush`` (host
backend call on the two-phase path), ``backpatch`` (jitted back-patch
dispatch). The register scan and fused classify live *inside* the
megastep's single dispatch — they are separated with ``jax.named_scope``
metadata in the jitted graphs (zero runtime cost) and show up in
profiler traces, not host timers.
"""

from __future__ import annotations

import collections
import contextlib
import time
from typing import Callable, Optional

import numpy as np

STAGES = ("ring_cut", "h2d", "megastep", "megastep_synced",
          "backend_flush", "backpatch")


class StageTimer:
    """Accumulate wall durations per named stage (bounded memory)."""

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 max_samples: int = 4096):
        self._clock = clock
        self._max = max_samples
        self._acc: dict = {}     # name -> [n, total_s, max_s, deque]

    @contextlib.contextmanager
    def stage(self, name: str):
        t0 = self._clock()
        try:
            yield
        finally:
            self.record(name, self._clock() - t0)

    def record(self, name: str, seconds: float) -> None:
        acc = self._acc.get(name)
        if acc is None:
            acc = self._acc[name] = [0, 0.0, 0.0,
                                     collections.deque(maxlen=self._max)]
        acc[0] += 1
        acc[1] += seconds
        acc[2] = max(acc[2], seconds)
        acc[3].append(seconds)

    @property
    def stages(self) -> tuple:
        return tuple(self._acc)

    def count(self, name: str) -> int:
        acc = self._acc.get(name)
        return acc[0] if acc else 0

    def total(self, name: str) -> float:
        acc = self._acc.get(name)
        return acc[1] if acc else 0.0

    def summary(self) -> dict:
        """stage -> {n, total_s, mean_ms, p50_ms, p95_ms, max_ms}."""
        out = {}
        for name, (n, total, mx, samples) in sorted(self._acc.items()):
            s = np.fromiter(samples, np.float64) * 1e3
            p50, p95 = (np.percentile(s, (50, 95)) if s.size
                        else (float("nan"), float("nan")))
            out[name] = {"n": n, "total_s": total,
                         "mean_ms": total / n * 1e3 if n else None,
                         "p50_ms": float(p50) if s.size else None,
                         "p95_ms": float(p95) if s.size else None,
                         "max_ms": mx * 1e3}
        return out

    def reset(self) -> None:
        self._acc.clear()


class SampledSync:
    """Every-N counter deciding which chunks get a blocking device sync.

    ``due()`` advances the counter and returns True on the N-th, 2N-th,
    ... call; ``every=0`` (default) never syncs — the zero-sync serving
    loop is preserved exactly.
    """

    def __init__(self, every: int = 0):
        if every < 0:
            raise ValueError(f"sync_every must be >= 0, got {every}")
        self.every = every
        self._i = 0

    def due(self) -> bool:
        if not self.every:
            return False
        self._i += 1
        if self._i >= self.every:
            self._i = 0
            return True
        return False


def annotation(name: str, enabled: bool = True):
    """``jax.profiler.TraceAnnotation`` context when enabled (and the
    profiler is importable), else a null context. Annotations are only
    visible inside a captured profiler trace; outside one they cost a
    TraceMe no-op."""
    if not enabled:
        return contextlib.nullcontext()
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # noqa: BLE001 — telemetry never raises; any
        #                profiler import/init failure degrades to a null
        #                context  # pragma: no cover - profiler unavailable
        return contextlib.nullcontext()
