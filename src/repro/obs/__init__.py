"""Unified observability for the serving loop (DESIGN.md §14).

One ``Observability`` object bundles the four obs primitives behind the
hooks the serving tiers call:

  events    ``obs.events``  — structured JSON-lines lifecycle events
                              (obs/events.py), bounded ring + optional
                              file sink;
  metrics   ``obs.metrics`` — counters/gauges/histograms plus snapshot
                              *sources* unifying StreamStats /
                              FaultStats / IngestStats / LatencyRecorder
                              behind one ``snapshot()`` (obs/metrics.py);
  rollups   ``obs.rollups`` — keyed per-N-dispatches windowed aggregation
                              (obs/metrics.RollupWindows);
  timing    ``obs.timer``   — per-stage wall timers with sampled device
                              synchronization (obs/profiling.py);
  drift     ``obs.drift``   — confidence-collapse / fraction_handled /
                              class-mix monitors over the rollup rows
                              (obs/drift.py), emitting ``drift_alarm``
                              events.

The contract with the serving tiers: a server built with ``obs=None``
(the default) takes NO observability branches — every hook site is
guarded by ``if obs is not None`` — and is bit-identical to pre-obs
serving. A server built with an ``Observability`` emits host-side events
and, once per ``rollup_every`` dispatches (a dispatch = one chunk
megastep or one window step), reads its device stats ONCE to close a
rollup window; at the default ``sync_every=0`` it never adds a blocking
device sync, so predictions stay bit-identical and throughput within the
BENCH_obs.json gate (≥0.9x).

Usage::

    obs = Observability(events_path="events.jsonl", rollup_every=8)
    srv = StreamingHybridServer(art, backend, chunk_windows=8, obs=obs)
    preds, stats = srv.serve_trace(trace)
    obs.snapshot()          # unified metrics + stage timings + drift
    obs.drift.alarms        # what fired (also "drift_alarm" events)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.drift import (DETECTORS, DriftAlarm, DriftConfig,
                             DriftMonitor)
from repro.obs.events import (EVENT_KINDS, EVENT_SCHEMA_VERSION, Event,
                              EventBus, EventSchemaError, JsonlSink,
                              iter_event_lines, validate_event_line,
                              validate_event_log)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               RollupWindows)
from repro.obs.profiling import (STAGES, SampledSync, StageTimer,
                                 annotation)

__all__ = [
    "DETECTORS", "DriftAlarm", "DriftConfig", "DriftMonitor",
    "EVENT_KINDS", "EVENT_SCHEMA_VERSION", "Event", "EventBus",
    "EventSchemaError", "JsonlSink", "iter_event_lines",
    "validate_event_line", "validate_event_log",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "RollupWindows",
    "STAGES", "SampledSync", "StageTimer", "annotation",
    "ObsConfig", "Observability",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Knobs of one Observability instance.

    events_path    JSON-lines sink file (None: in-memory ring only);
    max_events     in-memory event ring capacity;
    rollup_every   dispatches (chunk megasteps / window steps) per rollup
                   window — also the cadence of the ONE device-stats read
                   the serving loop takes per window;
    sync_every     sampled-synchronization cadence: every N-th dispatch
                   blocks until device-complete inside the
                   ``megastep_synced`` stage (0 = never, the default —
                   the zero-sync loop is preserved exactly);
    annotate       wrap megasteps in ``jax.profiler.TraceAnnotation``
                   (visible in captured profiler traces only);
    drift          DriftConfig of the monitors (None: defaults);
    drift_enabled  False disables drift detection entirely.
    """
    events_path: Optional[str] = None
    max_events: int = 65536
    rollup_every: int = 8
    sync_every: int = 0
    annotate: bool = False
    drift: Optional[DriftConfig] = None
    drift_enabled: bool = True

    def __post_init__(self):
        if self.rollup_every < 1:
            raise ValueError(f"rollup_every must be >= 1, "
                             f"got {self.rollup_every}")


class Observability:
    """The facade the serving tiers hold (see module doc).

    Construct from an ``ObsConfig`` or keyword knobs::

        Observability(rollup_every=4, events_path="events.jsonl")
    """

    def __init__(self, config: Optional[ObsConfig] = None, **knobs):
        if config is not None and knobs:
            raise ValueError("pass an ObsConfig or keyword knobs, not both")
        self.config = config or ObsConfig(**knobs)
        c = self.config
        sink = JsonlSink(c.events_path) if c.events_path else None
        self.events = EventBus(sink=sink, max_events=c.max_events)
        self.metrics = MetricsRegistry()
        # serving rollup samples are boundary deltas covering rollup_every
        # dispatches each, so every observed sample closes one row
        self.rollups = RollupWindows(every=1)
        self.timer = StageTimer()
        self.sync = SampledSync(c.sync_every)
        self.drift = DriftMonitor(c.drift) if c.drift_enabled else None
        self._ticks = 0           # dispatches since the last rollup row

    # -- serving hooks -------------------------------------------------------

    def bind(self, server, name: str = "server") -> None:
        """Register the server's stats objects as snapshot sources.

        Late-bound lambdas: the server replaces ``_stats`` every step and
        ``ingest_stats``/``latency`` every serve_stream, so sources read
        the *current* object at snapshot() time. Reading the stream
        source syncs its device scalars — snapshot() is a telemetry
        call, not a hot-path one.
        """
        self.metrics.register_source(
            f"{name}.stream", lambda: server.stats.as_dict())
        self.metrics.register_source(
            f"{name}.faults",
            lambda: (server.fault_stats.as_dict()
                     if server.fault_stats is not None else {}))
        self.metrics.register_source(
            f"{name}.ingest",
            lambda: (server.ingest_stats.as_dict()
                     if server.ingest_stats is not None else {}))
        self.metrics.register_source(
            f"{name}.latency",
            lambda: (server.latency.summary()
                     if server.latency is not None else {}))

    def emit(self, kind: str, **fields) -> Event:
        return self.events.emit(kind, **fields)

    def stage(self, name: str):
        """Time a pipeline stage (context manager)."""
        return self.timer.stage(name)

    def annotate(self, name: str):
        """Profiler trace annotation around a megastep (null context
        unless ``annotate`` is configured)."""
        return annotation(name, self.config.annotate)

    def sync_due(self) -> bool:
        """Sampled synchronization: should this dispatch block until
        device-complete (inside the ``megastep_synced`` stage)?"""
        return self.sync.due()

    def tick(self) -> bool:
        """Count one dispatch; True at each rollup boundary."""
        self._ticks += 1
        if self._ticks >= self.config.rollup_every:
            self._ticks = 0
            return True
        return False

    @property
    def pending_ticks(self) -> int:
        """Dispatches since the last rollup row (the end-of-stream
        partial window the serving loop flushes)."""
        return self._ticks

    def reset_ticks(self) -> None:
        self._ticks = 0

    def observe_rollup(self, sample: dict, key: str = "default") -> dict:
        """Close one rollup window from a boundary-delta sample: emit the
        ``rollup`` event, feed the drift monitors, emit a ``drift_alarm``
        event (and count a metric) per alarm. Returns the closed row."""
        row = self.rollups.observe(sample, key=key)   # every=1: closes now
        self.emit("rollup", key=key, window=row["window"],
                  packets=int(sample.get("packets", 0)),
                  dispatches=int(sample.get("dispatches", 0)))
        if self.drift is not None:
            for alarm in self.drift.observe(row):
                self.emit("drift_alarm", **alarm.as_fields())
                self.metrics.counter(
                    f"drift.{alarm.detector}").inc()
        return row

    # -- reading -------------------------------------------------------------

    @property
    def alarms(self) -> list:
        return self.drift.alarms if self.drift is not None else []

    def snapshot(self) -> dict:
        """Everything at once: the metrics registry snapshot (counters /
        gauges / histograms / sources), per-stage timings, event counts,
        and the drift state."""
        out = self.metrics.snapshot()
        out["stages"] = self.timer.summary()
        out["events"] = {"emitted": self.events.emitted,
                         "buffered": len(self.events),
                         "by_kind": self.events.counts()}
        out["drift"] = {
            "enabled": self.drift is not None,
            "alarms": [dataclasses.asdict(a) for a in self.alarms],
            "fired_detectors": list(
                self.drift.fired_detectors) if self.drift else [],
        }
        return out

    def close(self) -> None:
        self.events.close()
