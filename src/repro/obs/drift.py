"""Drift monitors over rollup windows: the hot-swap control plane's senses.

IIsy's switch tier serves a *frozen* small model; the hybrid design only
stays trustworthy while the traffic still looks like the training
distribution. ROADMAP item 1 (pForest-style phase-aware models, the
Planter train→map→deploy loop) needs exactly three signals to decide a
retrain/hot-swap, and this module computes them from the metric rollups
(``obs.metrics.RollupWindows`` rows):

  confidence collapse   mean switch confidence of a rollup window drops
                        ``conf_drop`` below the baseline — the small
                        model still answers, but no longer decisively;
  fraction_handled drop the share of packets answered at the switch
                        falls ``frac_drop`` below baseline — backend
                        load is growing, the paper's headline economy
                        is eroding;
  class-mix shift       the L1 distance between the window's predicted
                        class distribution and the baseline's exceeds
                        ``mix_l1`` — the traffic itself changed (attack
                        onset, new application mix), the strongest
                        retrain trigger.

Baseline: the mean over the first ``baseline_windows`` closed rollups
(per key), frozen once complete — drift is measured against how the
stream *started*, so a slow degradation cannot re-anchor its own
baseline window by window. Windows with fewer than ``min_packets``
packets are ignored both for the baseline and for detection (tiny drain
windows are noise). Detectors return ``DriftAlarm`` records; the
``Observability`` facade emits each as a ``drift_alarm`` event.

All host-side, all O(1) per rollup window: nothing here syncs a device
value (the serving loop's rollup boundary already produced plain
numbers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

DETECTORS = ("conf_collapse", "frac_handled_drop", "class_mix_shift")


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Thresholds of the three detectors (None disables a detector).

    conf_drop          absolute mean-confidence drop vs baseline that
                       fires ``conf_collapse``;
    frac_drop          absolute fraction_handled drop vs baseline that
                       fires ``frac_handled_drop``;
    mix_l1             L1 distance between predicted-class distributions
                       (in [0, 2]) that fires ``class_mix_shift``;
    baseline_windows   rollup windows averaged into the frozen baseline;
    min_packets        windows below this packet count are ignored.
    """
    conf_drop: Optional[float] = 0.15
    frac_drop: Optional[float] = 0.2
    mix_l1: Optional[float] = 0.5
    baseline_windows: int = 2
    min_packets: int = 64

    def __post_init__(self):
        for name in ("conf_drop", "frac_drop", "mix_l1"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0 or None, got {v}")
        if self.baseline_windows < 1:
            raise ValueError(f"baseline_windows must be >= 1, "
                             f"got {self.baseline_windows}")
        if self.min_packets < 0:
            raise ValueError(f"min_packets must be >= 0, "
                             f"got {self.min_packets}")


@dataclasses.dataclass(frozen=True)
class DriftAlarm:
    """One detector firing on one rollup window."""
    detector: str      # one of DETECTORS
    key: str           # rollup key (tenant-ready)
    window: int        # rollup window index that fired
    value: float       # the window's observed statistic
    baseline: float    # the frozen baseline statistic
    threshold: float   # the configured trip threshold

    def as_fields(self) -> dict:
        """Flat event-field form (drift_alarm events)."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Baseline:
    """Per-key frozen baseline, averaged over the first N valid windows."""
    n: int = 0
    conf_sum: float = 0.0
    frac_sum: float = 0.0
    mix_sum: Optional[np.ndarray] = None
    frozen: bool = False

    def fold(self, conf: float, frac: float, mix: np.ndarray) -> None:
        self.n += 1
        self.conf_sum += conf
        self.frac_sum += frac
        self.mix_sum = (mix.copy() if self.mix_sum is None
                        else self.mix_sum + mix)

    @property
    def conf(self) -> float:
        return self.conf_sum / self.n

    @property
    def frac(self) -> float:
        return self.frac_sum / self.n

    @property
    def mix(self) -> np.ndarray:
        return self.mix_sum / self.n


def _window_stats(row: dict):
    """(packets, mean_conf, frac_handled, class_dist) of one rollup row —
    None when the row is unusable (no packets)."""
    sums = row.get("sums", {})
    pkts = float(sums.get("packets", 0))
    if pkts <= 0:
        return None
    conf = float(sums.get("conf_sum", 0.0)) / pkts
    frac = float(sums.get("handled", 0)) / pkts
    counts = np.asarray(sums.get("class_counts", [pkts]), np.float64)
    total = counts.sum()
    dist = counts / total if total > 0 else counts
    return pkts, conf, frac, dist


class DriftMonitor:
    """Feed closed rollup rows in; get DriftAlarms out.

    ``observe(row)`` returns the (possibly empty) list of alarms the
    window tripped. Alarms accumulate in ``.alarms``; ``fired`` /
    ``fired_detectors`` summarize. ``reset()`` forgets baselines and
    alarms (a new stream epoch).
    """

    def __init__(self, config: Optional[DriftConfig] = None):
        self.config = config or DriftConfig()
        self.reset()

    def reset(self) -> None:
        self._baselines: dict = {}      # key -> _Baseline
        self.alarms: list = []
        self.windows_seen = 0

    @property
    def fired(self) -> bool:
        return bool(self.alarms)

    @property
    def fired_detectors(self) -> tuple:
        seen: list = []
        for a in self.alarms:
            if a.detector not in seen:
                seen.append(a.detector)
        return tuple(seen)

    def baseline_ready(self, key: str = "default") -> bool:
        b = self._baselines.get(key)
        return b is not None and b.frozen

    def observe(self, row: dict) -> list:
        """Fold one closed rollup row; -> list of DriftAlarm fired."""
        cfg = self.config
        stats = _window_stats(row)
        if stats is None:
            return []
        pkts, conf, frac, dist = stats
        if pkts < cfg.min_packets:
            return []
        self.windows_seen += 1
        key = row.get("key", "default")
        b = self._baselines.get(key)
        if b is None:
            b = self._baselines[key] = _Baseline()
        if not b.frozen:
            b.fold(conf, frac, dist)
            if b.n >= cfg.baseline_windows:
                b.frozen = True
            return []                     # baseline windows never alarm
        fired = []
        window = int(row.get("window", self.windows_seen))

        def alarm(detector, value, baseline, threshold):
            a = DriftAlarm(detector=detector, key=key, window=window,
                           value=float(value), baseline=float(baseline),
                           threshold=float(threshold))
            fired.append(a)
            self.alarms.append(a)

        if cfg.conf_drop is not None and b.conf - conf >= cfg.conf_drop:
            alarm("conf_collapse", conf, b.conf, cfg.conf_drop)
        if cfg.frac_drop is not None and b.frac - frac >= cfg.frac_drop:
            alarm("frac_handled_drop", frac, b.frac, cfg.frac_drop)
        if cfg.mix_l1 is not None:
            bm, dm = b.mix, dist
            if len(bm) != len(dm):        # class space grew: pad shorter
                n = max(len(bm), len(dm))
                bm = np.pad(bm, (0, n - len(bm)))
                dm = np.pad(dm, (0, n - len(dm)))
            l1 = float(np.abs(bm - dm).sum())
            if l1 >= cfg.mix_l1:
                alarm("class_mix_shift", l1, 0.0, cfg.mix_l1)
        return fired
